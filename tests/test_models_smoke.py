"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a reduced same-family config, runs one forward/train step and a
decode step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models import model as M
from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig

B, S = 2, 32


def _batch(cfg, rng):
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)))}
    if cfg.frontend:
        batch["frontend_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_tokens, cfg.frontend_dim)),
            jnp.float32,
        )
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_and_decode(arch):
    cfg = get_smoke_config(arch)
    rng = np.random.RandomState(0)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, rng)

    logits, aux = T.forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"

    step = jax.jit(M.make_train_step(cfg, AdamWConfig(lr=1e-3, clip_norm=1.0)))
    state = M.init_train_state(params, AdamWConfig(lr=1e-3))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0

    cache = M.init_cache(cfg, B, max_len=S + 4)
    serve = jax.jit(M.make_serve_step(cfg))
    dl, cache = serve(state["params"], cache, batch["tokens"][:, 0])
    assert dl.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(dl).all())
    assert int(cache["pos"]) == 1


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma3-27b", "xlstm-350m",
                                  "qwen2-moe-a2.7b", "hymba-1.5b"])
def test_prefill_matches_forward(arch):
    """prefill() must produce exactly the forward()'s last-position logits."""
    cfg = get_smoke_config(arch)
    rng = np.random.RandomState(1)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg, rng)
    cache = M.init_cache(cfg, B, max_len=S + 8)
    pre = jax.jit(M.make_prefill_step(cfg))
    pl, cache = pre(params, cache, batch)
    fl, _ = T.forward(params, batch, cfg)
    np.testing.assert_allclose(
        np.asarray(pl), np.asarray(fl[:, -1]), rtol=2e-4, atol=2e-4
    )
    assert int(cache["pos"]) == S


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "xlstm-350m", "hymba-1.5b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the teacher-forced forward
    logits (the KV/state cache equivalence test)."""
    cfg = get_smoke_config(arch)
    rng = np.random.RandomState(2)
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, 8)))
    fl, _ = T.forward(params, {"tokens": toks}, cfg)

    cache = M.init_cache(cfg, B, max_len=16)
    serve = jax.jit(M.make_serve_step(cfg))
    outs = []
    for t in range(8):
        dl, cache = serve(params, cache, toks[:, t])
        outs.append(dl)
    dec = jnp.stack(outs, axis=1)  # [B, 8, V]
    np.testing.assert_allclose(np.asarray(dec), np.asarray(fl), rtol=3e-3, atol=3e-3)


def test_sliding_window_cache_is_ring_buffer():
    """A local-attention cache must hold only `window` entries and decode
    correctly past the window boundary."""
    cfg = get_smoke_config("gemma3-27b").scaled(window=8)
    params = M.init_params(jax.random.PRNGKey(3), cfg)
    rng = np.random.RandomState(3)
    n = 20  # > 2x window
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, n)))
    fl, _ = T.forward(params, {"tokens": toks}, cfg)
    cache = M.init_cache(cfg, B, max_len=64)
    # local layers hold exactly window slots
    local_kv = cache["units"]["b0"]["kv"]["k"]
    assert local_kv.shape[2] == 8, local_kv.shape
    serve = jax.jit(M.make_serve_step(cfg))
    outs = []
    for t in range(n):
        dl, cache = serve(params, cache, toks[:, t])
        outs.append(dl)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(fl), rtol=3e-3, atol=3e-3)


def test_training_reduces_loss():
    """A few steps on a repeated batch must reduce the loss (end-to-end
    learning sanity for the substrate)."""
    cfg = get_smoke_config("tinyllama-1.1b")
    rng = np.random.RandomState(4)
    params = M.init_params(jax.random.PRNGKey(4), cfg)
    batch = _batch(cfg, rng)
    opt = AdamWConfig(lr=3e-3, clip_norm=1.0)
    step = jax.jit(M.make_train_step(cfg, opt))
    state = M.init_train_state(params, opt)
    losses = []
    for _ in range(12):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_grad_accum_matches_full_batch():
    cfg = get_smoke_config("tinyllama-1.1b")
    rng = np.random.RandomState(5)
    params = M.init_params(jax.random.PRNGKey(5), cfg)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (4, S)))}
    opt = AdamWConfig(lr=1e-3)
    s1 = M.init_train_state(params, opt)
    s2 = jax.tree.map(jnp.copy, s1)
    full = jax.jit(M.make_train_step(cfg, opt))
    accum = jax.jit(M.make_train_step(cfg, opt, grad_accum=2))
    s1, m1 = full(s1, batch)
    s2, m2 = accum(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    # parameters after one step agree (accumulated grads == full-batch grads)
    # note: Adam's first step is ~sign(g)*lr, so float accumulation-order
    # noise in tiny grads is amplified to ~lr-scale on isolated elements;
    # tolerance reflects that, not a semantic difference.
    l1 = jax.tree.leaves(s1["params"])
    l2 = jax.tree.leaves(s2["params"])
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=2e-5)


def test_full_configs_match_assignment():
    """The full (non-smoke) configs carry the exact assigned hyperparameters."""
    spec = {
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    }
    for arch, (L, d, H, kv, ff, V) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == H, arch
        assert cfg.n_kv_heads == kv, arch
        ff_actual = cfg.d_ff_expert if cfg.n_experts else cfg.d_ff
        assert ff_actual == ff, arch
        assert cfg.vocab_size == V, arch
    # MoE extras
    q2 = get_config("qwen2-moe-a2.7b")
    assert (q2.n_experts, q2.n_experts_active, q2.n_shared_experts) == (60, 4, 4)
    q3 = get_config("qwen3-moe-235b-a22b")
    assert (q3.n_experts, q3.n_experts_active) == (128, 8)
    assert get_config("hymba-1.5b").ssm_state == 16
    assert get_config("seamless-m4t-large-v2").encoder_layers == 24
