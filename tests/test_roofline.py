"""Roofline machinery unit tests: HLO collective parsing + term math."""
import numpy as np

from repro.configs import get_config, list_archs
from repro.launch.mesh import HW
from repro.launch.roofline import matmul_param_count, model_flops, roofline_terms
from repro.launch.shapes import SHAPES, cell_is_legal
from repro.utils.hlo import collective_bytes


def test_collective_parser_counts_ops():
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups=[2,8]<=[16]
  %ag = bf16[64,512]{1,0} all-gather(bf16[64,32]{1,0} %y), replica_groups={{0,1,2,3}}
  %rs = f32[16,16]{1,0} reduce-scatter(f32[256,16]{1,0} %z), replica_groups=[1,16]<=[16]
  %cp = f32[8,8]{1,0} collective-permute(f32[8,8]{1,0} %w)
  %aa = f32[32,32]{1,0} all-to-all(f32[32,32]{1,0} %v), replica_groups=[4,4]<=[16]
"""
    stats = collective_bytes(hlo, 16)
    assert stats.total_count == 5
    # all-reduce: 2 * 128*256*4 * 7/8
    ar = stats["all-reduce"]["bytes"]
    np.testing.assert_allclose(ar, 2 * 128 * 256 * 4 * 7 / 8)
    # all-gather: result 64*512*2 * 3/4
    ag = stats["all-gather"]["bytes"]
    np.testing.assert_allclose(ag, 64 * 512 * 2 * 3 / 4)
    # collective-permute: full operand
    np.testing.assert_allclose(stats["collective-permute"]["bytes"], 8 * 8 * 4)


def test_collective_parser_skips_done_halves():
    hlo = """
  %s = f32[64]{0} all-gather-start(f32[4]{0} %x), replica_groups=[1,16]<=[16]
  %d = f32[64]{0} all-gather-done(f32[64]{0} %s)
"""
    stats = collective_bytes(hlo, 16)
    assert stats.total_count == 1


def test_matmul_param_counts_are_sane():
    """Exact eval_shape counts land near the architectures' nameplate sizes."""
    expect_b = {
        "qwen2.5-14b": (13.0, 16.0),
        "tinyllama-1.1b": (0.9, 1.2),
        "minitron-8b": (7.0, 10.5),  # assignment d_ff=16384 > hf config's
        "gemma3-27b": (25.0, 29.5),
        "internvl2-2b": (1.5, 2.3),  # backbone only (ViT is a stub)
        "qwen3-moe-235b-a22b": (220.0, 245.0),
        "hymba-1.5b": (1.2, 1.9),
        "xlstm-350m": (0.3, 0.6),
    }
    for arch, (lo, hi) in expect_b.items():
        n = matmul_param_count(arch)
        cfg = get_config(arch)
        total_b = (n + cfg.vocab_size * cfg.d_model) / 1e9
        assert lo <= total_b <= hi, (arch, total_b)
    # MoE active params: qwen3 is ~22B active of ~235B total
    active = matmul_param_count("qwen3-moe-235b-a22b", active_only=True)
    assert 15e9 < active < 30e9, active


def test_model_flops_kinds():
    f_train = model_flops("tinyllama-1.1b", "train_4k")
    f_prefill = model_flops("tinyllama-1.1b", "prefill_32k")
    f_decode = model_flops("tinyllama-1.1b", "decode_32k")
    assert f_train > f_prefill > f_decode
    # train: 6ND with N~1.05B matmul params, D=1M tokens
    assert 5e15 < f_train < 8e15, f_train


def test_roofline_terms_dominance():
    rec = {
        "arch": "tinyllama-1.1b", "shape": "train_4k", "n_devices": 256,
        "flops_total": 5e13, "bytes_accessed_total": 1e12,
        "collective_bytes_per_device": 5e11,
    }
    t = roofline_terms(rec)
    assert t["compute_s"] == 5e13 / HW.PEAK_FLOPS_BF16
    assert t["dominant"] in ("compute", "memory", "collective")
    assert 0 < t["roofline_fraction"] <= 1.5
    assert t["useful_ratio"] > 0


def test_long_context_legality_matrix():
    legal = {a for a in list_archs()
             if cell_is_legal(get_config(a), SHAPES["long_500k"])}
    assert legal == {"gemma3-27b", "hymba-1.5b", "xlstm-350m"}
