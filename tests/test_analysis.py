"""Tests for ``repro.analysis``: the contract linter (each rule must catch
its seeded fixture and pass the clean twin), the inline allowlist protocol,
the CLI exit-code contract, the repo's own lint cleanliness, and the runtime
sanitizers (bank/result contract rejections, retrace budgets, lock
discipline, and the prefetch stress parity run)."""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
from pathlib import Path
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.analysis import lint_paths, sanitize
from repro.analysis.sanitize import (
    BankContractError,
    LockDisciplineError,
    ResultContractError,
    RetraceBudgetError,
)
from repro.core import fleet as fleet_mod
from repro.core.engine import make_bank_params, simulate_bank
from repro.core.fleet import Fleet
from repro.core.scenarios import sample_scenarios
from repro.core.workload import compile_bank

ROOT = Path(__file__).resolve().parents[1]
FIXTURES = ROOT / "tests" / "lint_fixtures"


# -- linter: each rule catches its fixture and passes the clean twin --------


@pytest.fixture(scope="module")
def fixture_report():
    return lint_paths([str(FIXTURES)])


def _rel(path: str) -> str:
    return path.replace("\\", "/").rsplit("lint_fixtures/", 1)[-1]


def _violations(report, filename: str):
    return [
        f for f in report.violations if _rel(f.path).endswith(filename)
    ]


CASES = [
    # (rule, seeded fixture, expected violation lines, clean twin)
    ("trace-purity", "trace_purity_bad.py", {12, 19, 20}, "trace_purity_ok.py"),
    ("rng-discipline", "rng_bad.py", {7, 12, 13, 19, 24}, "rng_ok.py"),
    ("pad-sentinel", "kernels/pad_bad.py", {13, 14, 16, 17}, "kernels/pad_ok.py"),
    ("jit-cache", "jit_cache_bad.py", {9, 14, 26}, "jit_cache_ok.py"),
]


@pytest.mark.parametrize("rule,bad,lines,clean", CASES, ids=[c[0] for c in CASES])
def test_rule_catches_fixture_and_passes_clean_twin(
    fixture_report, rule, bad, lines, clean
):
    bad_hits = _violations(fixture_report, bad)
    assert bad_hits, f"{rule}: no violations found in {bad}"
    assert all(f.rule == rule for f in bad_hits)
    assert {f.line for f in bad_hits} == lines
    assert not _violations(fixture_report, clean), (
        f"{rule}: clean twin {clean} must produce zero findings"
    )


def test_allowlist_protocol(fixture_report):
    hits = _violations(fixture_report, "allowlist_cases.py")
    # reason-less and wrong-rule tags stay violations; the justified one not
    assert {f.line for f in hits} == {13, 19}
    reasonless = next(f for f in hits if f.line == 13)
    assert "missing a `-- reason`" in reasonless.message
    allowed = [
        f
        for f in fixture_report.allowlisted
        if _rel(f.path).endswith("allowlist_cases.py")
    ]
    assert [f.line for f in allowed] == [7]
    assert "warm-up draw" in allowed[0].allow_reason


def test_rule_filter_runs_only_requested_rules():
    report = lint_paths([str(FIXTURES)], rules=["pad-sentinel"])
    assert {f.rule for f in report.findings} == {"pad-sentinel"}
    with pytest.raises(ValueError, match="unknown rule"):
        lint_paths([str(FIXTURES)], rules=["no-such-rule"])


def test_repo_source_is_lint_clean():
    """The shipping tree must hold zero violations (allowlisted entries are
    fine — they carry a written justification)."""
    report = lint_paths([str(ROOT / "src")])
    assert report.files_scanned > 20
    msgs = [f.format() for f in report.violations]
    assert not msgs, "repo lint violations:\n" + "\n".join(msgs)


def _run_cli(*args: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True,
        text=True,
        env=env,
        cwd=str(ROOT),
    )


def test_cli_strict_exit_codes_and_json_report(tmp_path):
    out = tmp_path / "report.json"
    proc = _run_cli(str(FIXTURES), "--strict", "--json", str(out))
    assert proc.returncode == 1, proc.stderr
    payload = json.loads(out.read_text())
    assert payload["files_scanned"] == 9
    assert any(f["rule"] == "trace-purity" for f in payload["findings"])
    assert any(f["allowlisted"] for f in payload["findings"])

    clean = _run_cli(str(FIXTURES / "rng_ok.py"), "--strict")
    assert clean.returncode == 0, clean.stdout + clean.stderr

    usage = _run_cli(str(FIXTURES), "--rules", "bogus")
    assert usage.returncode == 2


# -- sanitizers: bank contract rejections -----------------------------------


@pytest.fixture(scope="module")
def pairs():
    return sample_scenarios(None, 3, seed=0)


@pytest.fixture(scope="module")
def mono_bank(pairs):
    return compile_bank(list(pairs))


@pytest.fixture(scope="module")
def bucketed_bank(pairs):
    return compile_bank(list(pairs), n_buckets=2)


def test_check_bank_accepts_compiled_banks(mono_bank, bucketed_bank):
    sanitize.check_bank(mono_bank)
    sanitize.check_bank(bucketed_bank)


def test_check_bank_rejects_live_pad_leg(mono_bank):
    pad = ~np.asarray(mono_bank.leg_valid, bool)
    assert pad.any(), "fixture bank needs at least one padded leg"
    size = np.array(mono_bank.size_mb, copy=True)
    size[np.nonzero(pad)[0][0], np.nonzero(pad)[1][0]] = 64.0
    bad = dataclasses.replace(mono_bank, size_mb=size)
    with pytest.raises(BankContractError, match="size_mb"):
        sanitize.check_bank(bad)


def test_check_bank_rejects_out_of_bounds_dep(mono_bank):
    dep = np.array(mono_bank.dep, copy=True)
    dep[0, 0] = mono_bank.pad_legs + 5
    with pytest.raises(BankContractError, match="dep bounds"):
        sanitize.check_bank(dataclasses.replace(mono_bank, dep=dep))


def test_check_bank_rejects_dep_onto_padded_leg(mono_bank):
    n_legs = np.asarray(mono_bank.n_legs)
    s = int(np.argmin(n_legs))
    assert n_legs[s] < mono_bank.pad_legs
    dep = np.array(mono_bank.dep, copy=True)
    dep[s, 0] = n_legs[s]  # first padded slot of that scenario
    with pytest.raises(BankContractError, match="padded leg"):
        sanitize.check_bank(dataclasses.replace(mono_bank, dep=dep))


def test_check_bank_rejects_non_prefix_valid_mask(mono_bank):
    valid = np.array(mono_bank.leg_valid, copy=True)
    s = int(np.argmin(np.asarray(mono_bank.n_legs)))
    valid[s, -1] = True  # hole in the prefix: counts now disagree
    with pytest.raises(BankContractError):
        sanitize.check_bank(dataclasses.replace(mono_bank, leg_valid=valid))


def test_check_bank_rejects_live_shard_pad(mono_bank):
    names = list(mono_bank.names)
    names[0] = "__shard_pad__0"  # claims pad status yet holds real legs
    with pytest.raises(BankContractError, match="shard-pad"):
        sanitize.check_bank(dataclasses.replace(mono_bank, names=names))


def test_check_bank_rejects_broken_bucket_bijection(bucketed_bank):
    slot_of = np.array(bucketed_bank.slot_of, copy=True)
    bucket_of = np.asarray(bucketed_bank.bucket_of)
    b = int(bucket_of[0])
    mine = np.nonzero(bucket_of == b)[0]
    if mine.size > 1:
        slot_of[mine[0]], slot_of[mine[1]] = slot_of[mine[1]], slot_of[mine[0]]
        swapped = dataclasses.replace(bucketed_bank, slot_of=slot_of)
        # a swap keeps the slot set valid but breaks id agreement
        with pytest.raises(BankContractError, match="bucket"):
            sanitize.check_bank(swapped)
    slot_of = np.array(bucketed_bank.slot_of, copy=True)
    slot_of[mine[0]] = mine.size + 7
    with pytest.raises(BankContractError, match="slot_of out of range"):
        sanitize.check_bank(
            dataclasses.replace(bucketed_bank, slot_of=slot_of)
        )


def test_check_bank_once_memoizes(mono_bank):
    bank = dataclasses.replace(mono_bank)
    sanitize.check_bank_once(bank)
    assert getattr(bank, "_repro_bank_checked", False)
    # corrupting after the memo does not re-raise: validation ran once
    bank.dep = np.full_like(np.asarray(bank.dep), 999)
    sanitize.check_bank_once(bank)


# -- sanitizers: result contract rejections ---------------------------------


@pytest.fixture(scope="module")
def sim_result(mono_bank):
    keys = jax.random.split(jax.random.PRNGKey(0), mono_bank.n_scenarios)
    keys = keys.reshape(mono_bank.n_scenarios, 1, 2)
    return simulate_bank(mono_bank, make_bank_params(mono_bank), keys)


def test_check_result_accepts_engine_output(sim_result, mono_bank):
    sanitize.check_result(sim_result, mono_bank)


def test_check_result_rejects_nonfinite(sim_result):
    tt = np.array(sim_result.transfer_time, copy=True)
    tt[0, 0, 0] = np.nan
    with pytest.raises(ResultContractError, match="non-finite"):
        sanitize.check_result(sim_result._replace(transfer_time=tt))


def test_check_result_rejects_negative_durations(sim_result):
    tt = np.array(sim_result.transfer_time, copy=True)
    tt[0, 0, 0] = -1.0
    with pytest.raises(ResultContractError, match="negative transfer_time"):
        sanitize.check_result(sim_result._replace(transfer_time=tt))


def test_check_result_rejects_unmasked_unfinished_leg(sim_result):
    tt = np.asarray(sim_result.transfer_time)
    done = np.array(sim_result.done, copy=True)
    live = np.nonzero((tt > 0) & done)
    assert live[0].size, "fixture run needs a finished leg with time > 0"
    done[live[0][0], live[1][0], live[2][0]] = False
    with pytest.raises(ResultContractError, match="mask transfer_time"):
        sanitize.check_result(sim_result._replace(done=done))


def test_nan_guard_forces_engine_result_checks(mono_bank, monkeypatch):
    calls = []
    original = sanitize.check_result

    def counting(result, bank=None, **kw):
        calls.append(kw.get("where"))
        return original(result, bank, **kw)

    monkeypatch.setattr(sanitize, "check_result", counting)
    keys = jax.random.split(jax.random.PRNGKey(1), mono_bank.n_scenarios)
    keys = keys.reshape(mono_bank.n_scenarios, 1, 2)
    params = make_bank_params(mono_bank)
    assert not sanitize.result_checks_enabled()
    with sanitize.nan_guard():
        simulate_bank(mono_bank, params, keys)
    assert calls == ["simulate_bank"]
    simulate_bank(mono_bank, params, keys)
    assert calls == ["simulate_bank"]  # off again outside the scope


# -- sanitizers: retrace budget ---------------------------------------------


def test_retrace_guard_flags_and_passes(pairs):
    fl = Fleet(compile_bank(list(pairs)))
    with pytest.raises(RetraceBudgetError):
        with sanitize.retrace_guard(budget=0, reset=True):
            fl.run(replicas=1)
    # warm now: an identical run must stay within a zero budget
    with sanitize.retrace_guard(budget=0):
        fl.run(replicas=1)
    with pytest.raises(ValueError):
        with sanitize.retrace_guard(budget=-1):
            pass


# -- sanitizers: lock discipline & the prefetch stress run ------------------


def test_lock_discipline_catches_unlocked_mutation():
    with sanitize.lock_discipline():
        with pytest.raises(LockDisciplineError):
            fleet_mod._compile_cache["rogue"] = 1
        fleet_mod._cache_put(("disciplined",), 2)  # holds the lock: fine
        with fleet_mod._COMPILE_CACHE_LOCK:
            del fleet_mod._compile_cache[("disciplined",)]
    # scope exit restores a plain dict and keeps its contents
    assert type(fleet_mod._compile_cache) is dict
    fleet_mod._compile_cache["rogue"] = 1  # no lock needed anymore
    del fleet_mod._compile_cache["rogue"]


def test_thread_stress_restores_switch_interval():
    before = sys.getswitchinterval()
    with sanitize.thread_stress(1e-5):
        assert sys.getswitchinterval() == pytest.approx(1e-5)
    assert sys.getswitchinterval() == pytest.approx(before)


def test_stream_prefetch_parity_under_stress(pairs):
    """200 single-scenario chunks through ``Fleet.stream(prefetch=2)`` with
    a 10us bytecode switch interval and the lock-discipline checker armed:
    results must be bitwise identical to the synchronous path, with zero
    retraces after the first chunk."""
    fl = Fleet.from_pairs(list(pairs))
    stream_pairs = list(itertools.islice(itertools.cycle(pairs), 200))
    key = jax.random.PRNGKey(7)

    sync = list(fl.stream(stream_pairs, chunk=1, key=key))
    assert len(sync) == 200

    with sanitize.thread_stress(1e-5), sanitize.lock_discipline():
        with sanitize.retrace_guard(budget=2):
            pre = list(fl.stream(stream_pairs, chunk=1, key=key, prefetch=2))
    assert len(pre) == 200
    for a, b in zip(sync, pre):
        assert a.names == b.names
        for field in ("transfer_time", "conth_mb", "conpr_mb", "done"):
            assert np.array_equal(
                np.asarray(getattr(a.result, field)),
                np.asarray(getattr(b.result, field)),
            ), f"prefetch stream diverged on {field}"
