"""Clean twin of ``rng_bad.py``: every draw comes from a fresh split, loops
rebind per iteration, and fold_in derives per-item keys legitimately."""
import jax


def two_draws(key):
    key, k1 = jax.random.split(key)
    a = jax.random.normal(k1)
    key, k2 = jax.random.split(key)
    b = jax.random.uniform(k2)
    return a + b


def loop_split(key, n):
    total = 0.0
    for _ in range(n):
        key, sub = jax.random.split(key)
        total += jax.random.normal(sub)
    return total


def per_item(key, items):
    # fold_in is the documented per-item derivation, not consumption
    return [jax.random.normal(jax.random.fold_in(key, i)) for i in items]


def default_key(key=None):
    key = jax.random.PRNGKey(0) if key is None else key
    return jax.random.normal(key)
