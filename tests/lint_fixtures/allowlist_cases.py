"""Allowlist-protocol fixtures: a justified suppression, a reason-less allow
comment (still a violation), and a mismatched-rule tag (no effect)."""
import jax


def justified(key):
    jax.random.split(key)  # repro: allow[rng-discipline] -- fixture: deliberate warm-up draw kept for trace parity
    return key


def reasonless(key):
    # repro: allow[rng-discipline]
    jax.random.split(key)  # EXPECT: still a violation (no `-- reason`)
    return key


def wrong_rule(key):
    # repro: allow[jit-cache] -- tag names a different rule, must not apply
    jax.random.split(key)  # EXPECT: rng-discipline
    return key
