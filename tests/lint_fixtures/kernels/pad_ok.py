"""Clean twin of ``pad_bad.py``: every pad fill/compare goes through the
named workload sentinels."""
import numpy as np

from repro.core.workload import PAD_BG_PERIOD, PAD_PROFILE, PAD_PROTOCOL

T = 8


def rows(fill, n):
    return np.full((n,), fill)


def build_padded(tbl):
    profile = rows(PAD_PROFILE, T)
    protocol_id = np.full((T,), PAD_PROTOCOL)
    bank = dict(profile=profile, protocol_id=protocol_id)
    pad_tail(bank, bg_period=PAD_BG_PERIOD)
    if tbl.bg_period == PAD_BG_PERIOD:
        pass
    return bank


def pad_tail(bank, bg_period=0):
    bank["bg_period"] = bg_period
    return bank
