"""Seeded pad-sentinel violations. Lives under a ``kernels/`` directory so
the path-scoped rule applies. Not runnable engine code — parsed only."""
import numpy as np

T = 8


def rows(fill, n):
    return np.full((n,), fill)


def build_padded(tbl):
    profile = rows(-1, T)  # EXPECT: pad-sentinel (literal fill for profile)
    protocol_id = np.full((T,), -1)  # EXPECT: pad-sentinel
    bank = dict(profile=profile, protocol_id=protocol_id)
    pad_tail(bank, bg_period=1 << 30)  # EXPECT: pad-sentinel (kwarg literal)
    if tbl.bg_period == 1073741824:  # EXPECT: pad-sentinel (literal compare)
        pass
    return bank


def pad_tail(bank, bg_period=0):
    bank["bg_period"] = bg_period
    return bank
