"""Seeded rng-discipline violations."""
import jax


def double_draw(key):
    a = jax.random.normal(key)
    b = jax.random.uniform(key)  # EXPECT: rng-discipline (key reused)
    return a + b


def discarded_split(key):
    jax.random.split(key)  # EXPECT: rng-discipline (result discarded)
    return jax.random.normal(key)


def loop_reuse(key, n):
    total = 0.0
    for _ in range(n):
        total += jax.random.normal(key)  # EXPECT: rng-discipline (loop reuse)
    return total


def shadowed_seed(key):
    fresh = jax.random.PRNGKey(0)  # EXPECT: rng-discipline (key param ignored)
    return jax.random.normal(fresh)
