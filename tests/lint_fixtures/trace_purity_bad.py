"""Seeded trace-purity violations: impure calls and data-dependent Python
branching inside jit-reachable functions. Never imported at runtime — the
linter parses it. Expected findings are tagged ``# EXPECT:`` per line."""
import os
import time

import jax
import jax.numpy as jnp


def helper(x):
    t = time.time()  # EXPECT: trace-purity (reachable via entry below)
    return x + t


@jax.jit
def entry(x):
    y = helper(x)
    flag = os.environ.get("FIXTURE_FLAG")  # EXPECT: trace-purity
    if jnp.any(y > 0):  # EXPECT: trace-purity (data-dependent branch)
        y = y * 2
    return y, flag


def never_traced(x):
    # clean: not reachable from any jit root, impurity is fine here
    return x + time.time()
