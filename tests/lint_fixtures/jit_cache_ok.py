"""Clean twin of ``jit_cache_bad.py``: jits live at module scope with every
config-like keyword-only parameter named in static_argnames; array-typed
keyword params stay traced by design."""
from typing import Optional

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("mode", "window"))
def tuned(x, *, mode: str = "fast", window: int = 8):
    return jnp.sum(x) if mode == "fast" else jnp.mean(x * window)


@jax.jit
def traced_optional(x, *, bias: Optional[jax.Array] = None):
    return x if bias is None else x + bias


_double = jax.jit(lambda v: v * 2)


def uses_module_jit(x):
    return _double(x)
