"""Seeded jit-cache violations."""
import functools

import jax
import jax.numpy as jnp


def per_call_jit(x):
    f = jax.jit(lambda v: v * 2)  # EXPECT: jit-cache (fresh cache per call)
    return f(x)


def nested_jitted_def(x):
    @jax.jit  # EXPECT: jit-cache (jitted def inside a function body)
    def inner(v):
        return v + 1

    return inner(x)


@functools.partial(jax.jit, static_argnames=("mode",))
def missing_static(
    x,
    *,
    mode: str = "fast",
    window: int = 8,  # EXPECT: jit-cache (config-like, not static)
):
    return jnp.sum(x) if mode == "fast" else jnp.mean(x * window)
