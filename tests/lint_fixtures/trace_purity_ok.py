"""Clean twin of ``trace_purity_bad.py``: the jitted function branches with
``jnp.where`` and every impure call stays outside the traced call graph."""
import time

import jax
import jax.numpy as jnp


def pure_helper(x):
    return x * 2.0


@jax.jit
def entry(x):
    y = pure_helper(x)
    return jnp.where(y > 0, y * 2, y)


def host_side_timer(x):
    # impure, but never reachable from a jit root
    start = time.time()
    out = entry(x)
    return out, time.time() - start
