"""SBI stack tests: classifier learns ratios on a toy problem; MCMC samples a
known posterior; the full (reduced-scale) calibration recovers theta on the
production workload."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mcmc as mcmc_lib
from repro.core.calibration import (
    CalibrationConfig,
    PriorBox,
    calibrate,
    make_theta_mapper,
    presimulate_bank,
    simulate_coefficients,
    validate,
)
from repro.core.classifier import (
    ClassifierConfig,
    epoch_batch_starts,
    init_classifier,
    train_classifier,
)
from repro.core.engine import SimSpec
from repro.core.fleet import Fleet
from repro.core.scenarios import sample_scenarios
from repro.core.workload import compile_campaign, wlcg_production_workload


def test_classifier_init_topology():
    cfg = ClassifierConfig()
    params = init_classifier(jax.random.PRNGKey(0), cfg)
    # paper: 4 hidden layers x 128 units, 1 output
    assert params["w0"].shape == (6, 128)
    assert params["w1"].shape == (128, 128)
    assert params["w3"].shape == (128, 128)
    assert params["w4"].shape == (128, 1)
    assert len(params) == 10


def test_classifier_learns_toy_dependence():
    """x = theta + noise: the classifier must separate dependent pairs from
    shuffled pairs (accuracy well above chance)."""
    key = jax.random.PRNGKey(0)
    n = 8192
    k1, k2, k3 = jax.random.split(key, 3)
    theta = jax.random.uniform(k1, (n, 3))
    x = theta + 0.05 * jax.random.normal(k2, (n, 3))
    cfg = ClassifierConfig()
    params, metrics = train_classifier(k3, cfg, theta, x, epochs=6, batch_size=1024)
    assert float(metrics.accuracy) > 0.75


def test_mcmc_samples_known_ratio():
    """Plug an analytic 'classifier' into the chain: logit = -||theta - mu||^2
    / (2 s^2) corresponds to a Gaussian posterior around mu; the chain's
    sample mean/std must match."""
    mu = jnp.array([0.6, 0.4, 0.5])
    s = 0.08

    class _FakeParams(dict):
        pass

    # run_chain calls log_ratio(params, theta, x) -> emulate via monkeypatch
    import repro.core.mcmc as m

    orig = m.log_ratio
    try:
        m.log_ratio = lambda p, t, x: -jnp.sum((t - mu) ** 2) / (2 * s * s)
        res = m.run_chain(
            {"w0": jnp.zeros((6, 1))},  # placeholder
            jnp.zeros((3,)),
            jax.random.PRNGKey(1),
            n_samples=6000,
            burn_in=1500,
            step_size=0.12,
        )
    finally:
        m.log_ratio = orig
    samples = np.asarray(res.samples)
    assert 0.2 < float(res.accept_rate) < 0.95
    np.testing.assert_allclose(samples.mean(0), np.asarray(mu), atol=0.03)
    np.testing.assert_allclose(samples.std(0), s, atol=0.03)


def test_posterior_mode():
    samples = jnp.stack(
        [
            jnp.clip(0.3 + 0.05 * jax.random.normal(jax.random.PRNGKey(0), (4000,)), 0, 1),
            jnp.clip(0.7 + 0.05 * jax.random.normal(jax.random.PRNGKey(1), (4000,)), 0, 1),
        ],
        axis=1,
    )
    mode = np.asarray(mcmc_lib.posterior_mode(samples))
    np.testing.assert_allclose(mode, [0.3, 0.7], atol=0.05)


@pytest.mark.slow
def test_end_to_end_calibration_recovers_theta():
    """Reduced-scale paper Section 5: generate x_true from a known theta,
    calibrate, and check theta lands near the truth (mu/sigma especially —
    the paper finds overhead nearly unidentifiable, Fig. 5)."""
    grid, camp = wlcg_production_workload(seed=0)  # the 106-obs workload
    table = compile_campaign(grid, camp)
    spec = SimSpec.from_table(table, max_ticks=30_000)
    mapper = make_theta_mapper(table, "webdav")
    theta_true = jnp.array([0.02, 36.9, 14.4])
    x_true = simulate_coefficients(
        spec, mapper(theta_true), jax.random.PRNGKey(42), n_replicates=8
    )

    cfg = CalibrationConfig(
        n_presim=4096, epochs=120, batch_size=1024, lr=3e-4,
        n_replicates=2, n_chains=4, n_mcmc=6000, burn_in=1200, step_size=0.1,
        n_validation=16,
    )
    result = calibrate(spec, table, x_true, jax.random.PRNGKey(0), cfg)
    theta_map = np.asarray(result.theta_map)
    # mu is the strongly identified parameter (Fig. 5)
    assert abs(theta_map[1] - 36.9) < 25.0, theta_map
    # posterior must concentrate relative to the prior (std_uniform ~ 28.9)
    assert np.asarray(result.posterior_samples)[:, 1].std() < 26.0

    val = validate(
        spec, table, result.theta_map, x_true, jax.random.PRNGKey(9),
        n_sims=16, n_replicates=2,
    )
    # Eq.-6 errors: the dominant coefficients a, b recovered within ~35%
    # at this reduced budget (paper reaches ~5% at 12.7M presims)
    assert val["mean_abs_error"][0] < 0.35, val["mean_abs_error"]
    assert val["mean_abs_error"][1] < 0.50, val["mean_abs_error"]


def test_gelman_rubin_known_value():
    """Closed-form split-R-hat on hand-built chains. With chains whose split
    halves are [0,2,0,2]-patterned (within-var 4/3) and half-chain means
    (1, 1, 6, 6): B = 100/3, var_hat = 28/3, R-hat = sqrt(7). Means
    (1, 1, 2, 2) give var_hat = W = 4/3, R-hat exactly 1."""
    base = np.tile([0.0, 2.0], 4)  # one chain of 8: halves are [0,2,0,2]
    dim0 = np.stack([base, base + 5.0])  # half-chain means 1, 1, 6, 6
    dim1 = np.stack([base, base + 1.0])  # half-chain means 1, 1, 2, 2
    chains = jnp.asarray(np.stack([dim0, dim1], axis=-1))  # [2, 8, 2]
    rhat = np.asarray(mcmc_lib.gelman_rubin(chains))
    np.testing.assert_allclose(rhat, [np.sqrt(7.0), 1.0], rtol=1e-6)


def test_posterior_mode_bimodal():
    """The per-axis mode must pick the taller peak of a bimodal posterior,
    not the (prior-ward) mean."""
    rng = np.random.RandomState(0)
    col0 = np.concatenate(
        [0.25 + 0.02 * rng.standard_normal(3000),
         0.75 + 0.02 * rng.standard_normal(1000)]
    )
    col1 = np.concatenate(
        [0.25 + 0.02 * rng.standard_normal(1000),
         0.75 + 0.02 * rng.standard_normal(3000)]
    )
    samples = jnp.asarray(np.clip(np.stack([col0, col1], axis=1), 0.0, 1.0))
    mode = np.asarray(mcmc_lib.posterior_mode(samples))
    np.testing.assert_allclose(mode, [0.25, 0.75], atol=0.05)
    # the mean would sit between the modes — the estimator must not
    assert abs(float(samples[:, 0].mean()) - mode[0]) > 0.08


def test_epoch_batch_starts_covers_the_tail():
    """``n % batch_size`` tail tuples must train every epoch: the final
    step shifts back to end at n instead of being dropped."""
    np.testing.assert_array_equal(epoch_batch_starts(10, 4), [0, 4, 6])
    np.testing.assert_array_equal(epoch_batch_starts(8, 4), [0, 4])  # legacy
    np.testing.assert_array_equal(epoch_batch_starts(5, 5), [0])
    for n, b in [(10, 4), (1000, 512), (7, 3), (4097, 4096), (512, 512)]:
        starts = epoch_batch_starts(n, b)
        assert len(starts) == -(-n // b), (n, b)
        covered = np.zeros(n, bool)
        for s in starts:
            assert 0 <= s and s + b <= n, (n, b, s)
            covered[s:s + b] = True
        assert covered.all(), (n, b)
    with pytest.raises(ValueError):
        epoch_batch_starts(3, 4)


def test_train_epoch_runs_the_tail_step():
    """The epoch scan takes ceil(n / batch) optimizer steps — observable on
    the AdamW step counter — so the tail minibatch is actually trained."""
    from repro.core.classifier import _train_epoch
    from repro.train.optimizer import AdamWConfig, adamw_init

    cfg = ClassifierConfig(hidden=8, depth=2)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    params = init_classifier(k1, cfg)
    opt = adamw_init(params, AdamWConfig(lr=1e-3))
    n, b = 10, 4
    theta = jax.random.uniform(k2, (n, 3))
    x = jax.random.uniform(k3, (n, 3))
    ctx = jnp.zeros((n, 0))
    _, opt2, metrics = _train_epoch(
        params, opt, theta, x, ctx, jax.random.PRNGKey(1),
        jnp.asarray(1e-3), batch_size=b,
    )
    assert int(opt2.step) == 3  # ceil(10/4): 2 full steps + the tail step
    assert np.isfinite(float(metrics.loss))


def test_presimulate_bank_scenario_major_layout_and_bucket_parity():
    """Regression pin for the presim layout the amortized training pairs
    contexts by: ``(theta, x_sim, scenario_id)`` is scenario-major
    (scenario i owns rows [i*n_per, (i+1)*n_per)), and the bucketed layout
    reproduces the monolithic scenario_id/theta columns exactly — a silent
    reorder here would mispair contexts and poison the conditional net."""
    pairs = sample_scenarios(["wlcg-remote"], n=4, seed=0)
    mono = Fleet.from_pairs(pairs, max_ticks=6_000, leap=True)
    buck = Fleet.from_pairs(pairs, max_ticks=6_000, n_buckets=2, leap=True)
    prior = PriorBox.paper()
    key = jax.random.PRNGKey(3)
    n_per = 6
    t1, x1, s1 = presimulate_bank(mono, prior, key, n_per, batch=3)
    t2, x2, s2 = presimulate_bank(buck, prior, key, n_per, batch=3)

    assert t1.shape == (4 * n_per, 3) and x1.shape == (4 * n_per, 3)
    np.testing.assert_array_equal(
        np.asarray(s1), np.repeat(np.arange(4, dtype=np.int32), n_per)
    )
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    # same key -> identical prior draws, in the identical scenario-major
    # order, on both layouts
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    # and the simulated coefficients agree across layouts row for row
    np.testing.assert_allclose(
        np.asarray(x1), np.asarray(x2), rtol=1e-4, atol=1e-4
    )


def test_gelman_rubin_detects_mixing():
    """R-hat ~1 for well-mixed chains, >>1 for disjoint chains."""
    rng = np.random.RandomState(0)
    mixed = jnp.asarray(rng.standard_normal((4, 500, 3)))
    rhat = mcmc_lib.gelman_rubin(mixed)
    assert (np.asarray(rhat) < 1.1).all(), rhat
    # two chains stuck in different modes
    stuck = np.concatenate(
        [rng.standard_normal((2, 500, 3)), 10 + rng.standard_normal((2, 500, 3))]
    )
    rhat_bad = mcmc_lib.gelman_rubin(jnp.asarray(stuck))
    assert (np.asarray(rhat_bad) > 2.0).all(), rhat_bad


def test_adaptive_chain_hits_target_acceptance():
    """Robbins-Monro adaptation lands near the 0.44 target without a
    hand-tuned step size."""
    mu = jnp.array([0.5, 0.5, 0.5])
    s = 0.05
    import repro.core.mcmc as m

    orig = m.log_ratio
    try:
        m.log_ratio = lambda p, t, x: -jnp.sum((t - mu) ** 2) / (2 * s * s)
        res = m.run_chain_adaptive(
            {"w0": jnp.zeros((6, 1))}, jnp.zeros((3,)), jax.random.PRNGKey(0),
            n_samples=4000, burn_in=2000,
        )
    finally:
        m.log_ratio = orig
    assert 0.25 < float(res.accept_rate) < 0.65, float(res.accept_rate)
    samples = np.asarray(res.samples)
    np.testing.assert_allclose(samples.mean(0), np.asarray(mu), atol=0.03)
