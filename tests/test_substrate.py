"""Substrate tests: checkpointing (atomic, elastic), trainer loop with
restart, straggler monitor, data pipeline determinism, serving engine,
gradient compression, pipeline parallelism, gridfeed."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.configs import get_smoke_config
from repro.data.tokens import TokenStream, TokenStreamConfig, make_batch
from repro.models import model as M
from repro.train.optimizer import compress_grads, decompress_grads
from repro.train.trainer import StragglerMonitor, Trainer, TrainerConfig


# ---------------------------------------------------------------------------
# checkpoint store
# ---------------------------------------------------------------------------
def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16), "step": jnp.asarray(7)},
    }


def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    tree = _tree()
    store.save(10, tree)
    restored, step = store.restore(jax.tree.map(jnp.zeros_like, tree))
    assert step == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_retention_and_latest(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        store.save(s, _tree())
    assert store.latest_step() == 3
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000002", "step_00000003"]


def test_checkpoint_incomplete_is_ignored(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=3)
    store.save(1, _tree())
    # simulate a crash: a later checkpoint without the commit marker
    broken = tmp_path / "step_00000002"
    broken.mkdir()
    (tmp_path / "latest").write_text("step_00000002")
    assert store.latest_step() == 1  # falls back to last committed


def test_checkpoint_async(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    store.save(5, _tree(), blocking=False)
    store.wait()
    assert store.latest_step() == 5


def test_checkpoint_elastic_reshard(tmp_path):
    """Save replicated, restore with an explicit sharding (elastic path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    store = CheckpointStore(str(tmp_path))
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    store.save(1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    restored, _ = store.restore(jax.tree.map(jnp.zeros_like, tree), shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding == sh["w"]


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_token_stream_deterministic_resume():
    cfg = TokenStreamConfig(vocab_size=128, seq_len=32, global_batch=4, seed=3)
    s1 = TokenStream(cfg)
    batches = [next(s1) for _ in range(5)]
    s2 = TokenStream(cfg, start_index=3)
    np.testing.assert_array_equal(next(s2)["tokens"], batches[3]["tokens"])
    # pure function of index
    np.testing.assert_array_equal(
        make_batch(cfg, 2)["tokens"], batches[2]["tokens"]
    )


def test_token_stream_is_learnable():
    """The synthetic stream has sub-uniform entropy (copy structure)."""
    cfg = TokenStreamConfig(vocab_size=64, seq_len=128, global_batch=8)
    toks = make_batch(cfg, 0)["tokens"]
    assert toks.min() >= 0 and toks.max() < 64
    # repeated batches differ
    assert not np.array_equal(toks, make_batch(cfg, 1)["tokens"])


# ---------------------------------------------------------------------------
# trainer: checkpoint/restart continuity + straggler monitor
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_trainer_restart_continuity(tmp_path):
    cfg = get_smoke_config("tinyllama-1.1b")
    tcfg = TrainerConfig(
        total_steps=8, checkpoint_every=4, checkpoint_dir=str(tmp_path),
        log_every=100, peak_lr=1e-3, warmup_steps=2,
    )
    tr1 = Trainer(cfg, tcfg, seq_len=64, global_batch=2)
    out1 = tr1.run(steps=4)  # stops mid-run at the checkpoint boundary
    assert tr1.store.latest_step() == 4

    # a "new process" resumes from the checkpoint and finishes
    tr2 = Trainer(cfg, tcfg, seq_len=64, global_batch=2)
    out2 = tr2.run()
    assert int(out2["state"]["step"]) == 8

    # an uninterrupted run produces the same final loss trajectory
    tr3 = Trainer(
        cfg,
        TrainerConfig(
            total_steps=8, checkpoint_every=100,
            checkpoint_dir=str(tmp_path / "uninterrupted"),
            log_every=100, peak_lr=1e-3, warmup_steps=2,
        ),
        seq_len=64, global_batch=2,
    )
    out3 = tr3.run()
    np.testing.assert_allclose(
        out2["losses"], out3["losses"][4:], rtol=2e-4, atol=2e-5
    )


def test_straggler_monitor():
    mon = StragglerMonitor(factor=2.0)
    for _ in range(10):
        assert not mon.observe(0.1)
    assert mon.observe(0.5)  # 5x the EMA
    assert mon.events == 1
    assert not mon.observe(0.1)  # EMA unpoisoned


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
def test_grad_compression_error_feedback_is_unbiased():
    rng = np.random.RandomState(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)) * 1e-3, jnp.float32)}
    err = None
    acc = jnp.zeros((64, 64), jnp.float32)
    for _ in range(50):
        q, err = compress_grads(g, err)
        acc = acc + decompress_grads(q)["w"]
    # mean compressed grad converges to the true grad (error feedback)
    np.testing.assert_allclose(
        np.asarray(acc / 50), np.asarray(g["w"]), rtol=0, atol=2e-6
    )


# ---------------------------------------------------------------------------
# serving engine (retired -> repro.serve; the shim must fail loudly)
# ---------------------------------------------------------------------------
def test_serving_shim_points_to_repro_serve():
    with pytest.raises(ImportError, match="repro.serve"):
        from repro.serving import ServingEngine  # noqa: F401


# ---------------------------------------------------------------------------
# pipeline parallelism (host-mesh demonstration)
# ---------------------------------------------------------------------------
def test_pipeline_apply_matches_sequential():
    from repro.parallel.pipeline import bubble_fraction, pipeline_apply

    if jax.device_count() < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("stage",))
    n_stages, n_micro, mb, d = 1, 4, 2, 8
    rng = np.random.RandomState(0)
    params = {"w": jnp.asarray(rng.standard_normal((n_stages, d, d)), jnp.float32)}
    x = jnp.asarray(rng.standard_normal((n_micro, mb, d)), jnp.float32)

    def stage_fn(p, h):
        return jnp.tanh(h @ p["w"])

    out = pipeline_apply(mesh, stage_fn, params, x)
    expected = jnp.tanh(x @ params["w"][0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=1e-5, atol=1e-5)
    assert 0 <= bubble_fraction(4, 8) < 1


# ---------------------------------------------------------------------------
# grid-simulated data feed
# ---------------------------------------------------------------------------
def test_gridfeed_stall_model_and_optimizer():
    from repro.data.gridfeed import GridFeed, GridFeedConfig

    feed = GridFeed(GridFeedConfig(n_shards=16, n_workers=4, bg_mu=8.0,
                                   bg_sigma=2.0))
    arrivals = feed.plan()
    assert arrivals.shape[0] == 16
    assert (np.diff(arrivals) >= 0).all()
    stall, frac = feed.stall_time(step_time_s=1.0)
    assert 0 <= frac < 1
    best, fitness, hist = feed.optimize(generations=4, population=12)
    assert np.isfinite(fitness)
    assert hist[-1] <= hist[0] + 1e-6
