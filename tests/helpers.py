"""Shared test fixtures: small grids/campaigns for the engine tests, plus an
optional-``hypothesis`` shim so property-based tests skip (rather than fail at
collection) when the dependency is absent."""
from __future__ import annotations

from typing import List, Tuple

import numpy as np
import pytest

# re-exported for the property-based tests (`from helpers import given, ...`)
__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised in minimal envs
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Any ``st.<strategy>(...)`` call returns an inert placeholder."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _StrategyStub()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            # zero-arg stand-in: pytest must not see the property arguments
            # as fixtures, so the original signature is deliberately dropped
            def skipper():
                pytest.skip("hypothesis not installed")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

from repro.core.topology import Grid
from repro.core.workload import (
    AccessProfileKind,
    Campaign,
    FileAccess,
    Job,
    LegTable,
    Replica,
    compile_campaign,
)


def small_grid(
    bw_se_se: float = 100.0,
    bw_se_wn: float = 200.0,
    bw_wan: float = 50.0,
    bg=(0.0, 0.0),
    period: int = 16,
) -> Grid:
    g = Grid()
    g.add_data_center("A")
    g.add_data_center("B")
    g.add_storage_element("seA", "A")
    g.add_storage_element("seB", "B")
    g.add_worker_node("wn0", "B")
    g.add_worker_node("wn1", "B")
    g.add_link("seA", "seB", bw_se_se, bg[0], bg[1], period)
    g.add_link("seB", "wn0", bw_se_wn, bg[0], bg[1], period)
    g.add_link("seA", "wn0", bw_wan, bg[0], bg[1], period)
    g.add_link("seB", "wn1", bw_se_wn, bg[0], bg[1], period)
    g.add_link("seA", "wn1", bw_wan, bg[0], bg[1], period)
    return g


def mixed_campaign(seed: int = 0, n_jobs: int = 3, n_accesses: int = 4) -> Tuple[Grid, Campaign, LegTable]:
    """Random mixed-profile campaign on the small grid."""
    rng = np.random.RandomState(seed)
    g = small_grid()
    jobs: List[Job] = []
    for j in range(n_jobs):
        wn = f"wn{j % 2}"
        accs: List[FileAccess] = []
        for _ in range(n_accesses):
            kind = rng.randint(3)
            size = float(rng.uniform(20.0, 400.0))
            release = int(rng.randint(0, 30))
            if kind == 0:
                accs.append(
                    FileAccess(
                        Replica(size, "seA"),
                        AccessProfileKind.DATA_PLACEMENT,
                        "gsiftp",
                        release_tick=release,
                        local_storage_element="seB",
                    )
                )
            elif kind == 1:
                accs.append(
                    FileAccess(
                        Replica(size, "seB"),
                        AccessProfileKind.STAGE_IN,
                        "xrdcp",
                        release_tick=release,
                    )
                )
            else:
                accs.append(
                    FileAccess(
                        Replica(size, "seA"),
                        AccessProfileKind.REMOTE,
                        "webdav",
                        release_tick=release,
                    )
                )
        jobs.append(Job(wn, tuple(accs), name=f"j{j}"))
    camp = Campaign(tuple(jobs))
    table = compile_campaign(g, camp)
    return g, camp, table
