"""Parity suite for fused multi-tick simulation windows.

The windowed engine (``window=K``) must be **bit-identical** to per-tick
execution (``window=1``) for every lowering and every K — including the
stochastic background RNG stream, frozen carries of finished
(scenario, replica) elements at window boundaries, and the event-leap
interaction (leap windows leap, they never degrade to dt=1). Pinned here:

- per-sim ``simulate`` and the vmap bank lowering (inner-scan freeze mask);
- the manual banked lowering through ``ops.grid_tick_bank_fused``;
- the bucketed fleet path (per-bucket window resolution) and streamed
  fleets (shared-trace chunk banks);
- the fused Pallas kernel against the reference scan under
  ``interpret=True``;
- the host-driven stepped program with donated carry buffers.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (
    SimSpec,
    bank_spec,
    count_bank_traces,
    default_tick_window,
    make_bank_params,
    make_params,
    reset_bank_trace_count,
    simulate,
    simulate_bank,
    simulate_bank_stepped,
)
from repro.core.fleet import Fleet
from repro.core.scenarios import build_bank, sample_scenarios
from repro.core.workload import compile_bank
from repro.kernels import ops

FIELDS = ("transfer_time", "conth_mb", "conpr_mb", "done", "ticks",
          "start_tick", "profile", "size_mb")
WINDOWS = (7, 64, 10**6)  # covers K <, ~ and >> every max_ticks used here


def _keys(n, r=2, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n * r).reshape(n, r, 2)


def _assert_bitwise(a, b, msg=""):
    for f in FIELDS:
        x = np.asarray(getattr(a, f))
        y = np.asarray(getattr(b, f))
        np.testing.assert_array_equal(x, y, err_msg=f"{msg}{f}")


def _assert_close(a, b, msg="", rtol=1e-5, atol=1e-5):
    for f in FIELDS:
        np.testing.assert_allclose(
            np.asarray(getattr(a, f), np.float64),
            np.asarray(getattr(b, f), np.float64),
            rtol=rtol, atol=atol, err_msg=f"{msg}{f}",
        )


# ---------------------------------------------------------------------------
# per-sim and vmap lowering
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("leap", [False, True])
def test_simulate_windowed_bitwise(leap):
    """The per-sim loop: K fused ticks == K per-tick iterations, bit for
    bit, stochastic background included."""
    bank = build_bank(n=1, seed=3, max_ticks=400)
    table = bank.scenario_table(0)
    spec = SimSpec.from_table(table, max_ticks=400)
    params = make_params(table, bg_mu=4.0, bg_sigma=2.0)
    key = jax.random.PRNGKey(5)
    base = simulate(spec, params, key, leap=leap, window=1)
    for k in (7, 64, 417):
        win = simulate(spec, params, key, leap=leap, window=k)
        _assert_bitwise(base, win, msg=f"leap={leap} K={k} ")


@pytest.mark.parametrize("leap", [False, True])
@pytest.mark.parametrize("lowering", ["vmap", "banked"])
def test_bank_windowed_bitwise(leap, lowering):
    n = 4
    bank = build_bank(n=n, seed=8, max_ticks=2_000)
    params = make_bank_params(bank, bg_mu=5.0, bg_sigma=2.0)
    keys = _keys(n, 3, seed=8)
    base = simulate_bank(bank, params, keys, leap=leap, lowering=lowering,
                         window=1)
    for k in WINDOWS:
        win = simulate_bank(bank, params, keys, leap=leap, lowering=lowering,
                            window=k)
        _assert_bitwise(base, win, msg=f"{lowering} leap={leap} K={k} ")


def test_stochastic_keep_frac_rng_stream_parity():
    """Per-(scenario, replica) keep fractions — the calibration
    presimulation shape — keep the exact RNG stream across window sizes,
    and the windowed lowerings still agree with each other."""
    n, r = 3, 4
    bank = build_bank(["wlcg-remote", "bursty"], n=n, seed=9, max_ticks=2_000)
    base_p = make_bank_params(bank, bg_mu=3.0, bg_sigma=1.5)
    rng = np.random.RandomState(0)
    keep = np.broadcast_to(
        np.asarray(base_p.keep_frac)[:, None, :], (n, r, bank.pad_legs)
    ) * rng.uniform(0.9, 1.0, (n, r, 1)).astype(np.float32)
    params = base_p._replace(
        keep_frac=jnp.asarray(keep),
        bg_mu=jnp.broadcast_to(base_p.bg_mu[:, None, :], (n, r, bank.pad_links)),
        bg_sigma=jnp.broadcast_to(
            base_p.bg_sigma[:, None, :], (n, r, bank.pad_links)
        ),
    )
    keys = _keys(n, r, seed=9)
    for lowering in ("vmap", "banked"):
        base = simulate_bank(bank, params, keys, leap=True, lowering=lowering,
                             window=1)
        win = simulate_bank(bank, params, keys, leap=True, lowering=lowering,
                            window=16)
        _assert_bitwise(base, win, msg=f"{lowering} per-replica ")
    res_v = simulate_bank(bank, params, keys, leap=True, lowering="vmap",
                          window=16)
    res_b = simulate_bank(bank, params, keys, leap=True, lowering="banked",
                          window=16)
    _assert_close(res_v, res_b, msg="windowed cross-lowering ")


# ---------------------------------------------------------------------------
# bucketed and streamed fleets
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("leap", [False, True])
def test_bucketed_fleet_windowed_bitwise(leap):
    """Bucketed banks resolve the window per bucket (capped at each
    bucket's own tick bound's pow2 bracket) and stay bit-exact vs
    per-tick."""
    bank = compile_bank(sample_scenarios(n=8, seed=4), n_buckets=3)
    params = make_bank_params(bank, bg_mu=3.0, bg_sigma=1.0)
    keys = _keys(8, 2, seed=4)
    base = simulate_bank(bank, params, keys, leap=leap, window=1)
    for k in WINDOWS:
        win = simulate_bank(bank, params, keys, leap=leap, window=k)
        _assert_bitwise(base, win, msg=f"bucketed leap={leap} K={k} ")


def test_streamed_fleet_windowed_bitwise():
    pairs = sample_scenarios(n=6, seed=5)
    fleet = Fleet.from_pairs(pairs, max_ticks=2_000, leap=True)
    kw = dict(chunk=2, key=jax.random.PRNGKey(7), replicas=2, max_ticks=2_000)
    per_tick = [c.result for c in fleet.stream(iter(pairs), window=1, **kw)]
    windowed = [c.result for c in fleet.stream(iter(pairs), window=16, **kw)]
    assert len(per_tick) == len(windowed) == 3
    for i, (a, b) in enumerate(zip(per_tick, windowed)):
        _assert_bitwise(a, b, msg=f"stream chunk {i} ")


def test_fleet_window_default_and_override():
    fleet = Fleet.from_scenarios(n=2, seed=6, max_ticks=500, window=4)
    assert fleet.window == 4
    keys = _keys(2, 2, seed=6)
    res_default = fleet.run(keys=keys)          # fleet default window=4
    res_override = fleet.run(keys=keys, window=1)
    _assert_bitwise(res_default, res_override, msg="fleet window knob ")


# ---------------------------------------------------------------------------
# frozen carries at window boundaries
# ---------------------------------------------------------------------------

def test_frozen_carry_semantics_at_window_boundaries():
    """Scenarios with wildly different max_ticks freeze mid-window: the
    truncated scenario's clock (and every other carry) must stop exactly
    where the per-tick loop stops, for window sizes that straddle the
    boundary."""
    pairs = sample_scenarios(n=4, seed=12)
    bank = compile_bank(pairs, max_ticks=[5, 37, 2_000, 2_000])
    params = make_bank_params(bank, bg_mu=4.0, bg_sigma=2.0)
    keys = _keys(4, 3, seed=12)
    base = simulate_bank(bank, params, keys, lowering="banked", window=1)
    ticks = np.asarray(base.ticks)
    assert (ticks[0] <= 5).all() and (ticks[1] <= 37).all()
    assert (~np.asarray(base.done)).any(), "fixture must truncate some legs"
    for k in (2, 5, 7, 64):  # boundaries inside, at, and past the window
        win = simulate_bank(bank, params, keys, lowering="banked", window=k)
        _assert_bitwise(base, win, msg=f"frozen carry K={k} ")


# ---------------------------------------------------------------------------
# fused Pallas kernel (interpret mode) vs the reference scan
# ---------------------------------------------------------------------------

def test_fused_kernel_interpret_matches_ref_engine_level():
    """The whole banked engine on the fused interpret-mode kernel vs the
    XLA reference window — windows, freezes, and RNG re-sync included."""
    n = 4
    bank = build_bank(n=n, seed=11, max_ticks=2_000)
    params = make_bank_params(bank, bg_mu=4.0, bg_sigma=1.5)
    keys = _keys(n, 2, seed=11)
    res_x = simulate_bank(bank, params, keys, lowering="banked",
                          backend="xla", window=8)
    res_p = simulate_bank(bank, params, keys, lowering="banked",
                          backend="pallas_interpret", window=8)
    _assert_close(res_x, res_p, rtol=1e-4, atol=1e-3, msg="fused interpret ")
    # leap windows leap on the kernel path too (ref scan driving the
    # per-tick bank kernel)
    res_xl = simulate_bank(bank, params, keys, lowering="banked",
                           backend="xla", leap=True, window=8)
    res_pl = simulate_bank(bank, params, keys, lowering="banked",
                           backend="pallas_interpret", leap=True, window=8)
    _assert_close(res_xl, res_pl, rtol=1e-4, atol=1e-3, msg="leap interpret ")


def test_fused_kernel_interpret_matches_ref_op_level():
    """Raw ``ops.grid_tick_bank_fused`` in noise= mode: the Pallas kernel
    and the reference scan consume identical predrawn noise and must agree
    on every state array, alive-step counts included."""
    n = 3
    bank = build_bank(n=n, seed=14, max_ticks=300)
    spec = bank_spec(bank)
    params = make_bank_params(bank, bg_mu=2.0, bg_sigma=1.0)
    S, R, K = n, 2, 6
    L = bank.pad_links
    T = bank.pad_legs
    rng = np.random.RandomState(1)
    state = (
        jnp.zeros((S, R), jnp.int32),
        jnp.zeros((S, R), jnp.int32),
        jnp.broadcast_to(spec.size_mb[:, None, :], (S, R, T)),
        jnp.asarray(~np.broadcast_to(bank.leg_valid[:, None, :], (S, R, T))),
        jnp.zeros((S, R, T), bool),
        jnp.zeros((S, R, T), jnp.int32),
        jnp.zeros((S, R, T), jnp.int32),
        jnp.zeros((S, R, T), jnp.float32),
        jnp.zeros((S, R, T), jnp.float32),
        jnp.zeros((S, R, L), jnp.float32),
    )
    noise = jnp.asarray(rng.standard_normal((K, S, R, L)), jnp.float32)
    mu = params.bg_mu[:, None, :]
    sigma = params.bg_sigma[:, None, :]
    args = (
        spec.release, spec.dep, spec.bg_period, spec.max_ticks,
        params.keep_frac, spec.bandwidth, spec.leg_proc, spec.proc_link,
        spec.leg_link,
    )
    out_x = ops.grid_tick_bank_fused(
        state, mu, sigma, *args, window=K, backend="xla", noise=noise
    )
    out_p = ops.grid_tick_bank_fused(
        state, mu, sigma, *args, window=K, backend="pallas_interpret",
        noise=noise,
    )
    from repro.kernels.ref import BANK_WINDOW_STATE_FIELDS

    for name, x, p in zip(BANK_WINDOW_STATE_FIELDS, out_x, out_p):
        np.testing.assert_allclose(
            np.asarray(x, np.float64), np.asarray(p, np.float64),
            rtol=1e-5, atol=1e-4, err_msg=name,
        )


def test_fused_op_validates_inputs():
    state = tuple(jnp.zeros((1, 1)) for _ in range(10))
    mu = jnp.zeros((1, 1, 2))
    with pytest.raises(ValueError, match="exactly one of"):
        ops.grid_tick_bank_fused(
            state, mu, mu, *([jnp.zeros((1, 2))] * 9), window=4
        )
    with pytest.raises(ValueError, match="state must carry"):
        ops.grid_tick_bank_fused(
            state[:5], mu, mu, *([jnp.zeros((1, 2))] * 9), window=4,
            key=jnp.zeros((1, 1, 2), jnp.uint32),
        )


# ---------------------------------------------------------------------------
# stepped execution: donated carries, host-driven loop
# ---------------------------------------------------------------------------

def test_stepped_program_matches_and_donates_cleanly():
    """The host-driven stepped loop (donated carry buffers) reproduces the
    fused while-loop program bit for bit, emits no donation/copy warnings,
    and leaves the caller's keys untouched."""
    bank = build_bank(n=4, seed=11, max_ticks=2_000)
    params = make_bank_params(bank, bg_mu=4.0, bg_sigma=1.5)
    keys = _keys(4, 2, seed=11)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        stepped = simulate_bank_stepped(bank, params, keys, window=8)
        jax.block_until_ready(stepped.ticks)
    bad = [
        str(w.message) for w in caught
        if "donat" in str(w.message).lower() or "copy" in str(w.message).lower()
    ]
    assert not bad, f"donation must be warning- and copy-free: {bad}"
    fused = simulate_bank(bank, params, keys, lowering="banked", window=8)
    _assert_bitwise(fused, stepped, msg="stepped ")
    # the caller's keys buffer must survive the donated init carry
    assert np.asarray(keys).shape == (4, 2, 2)


# ---------------------------------------------------------------------------
# window resolution and trace behavior
# ---------------------------------------------------------------------------

def test_window_resolution(monkeypatch):
    assert default_tick_window() >= 1
    assert default_tick_window(leap=True) >= 1
    bank = build_bank(n=2, seed=0, max_ticks=200)
    params = make_bank_params(bank)
    keys = _keys(2, 1)
    with pytest.raises(ValueError, match="window"):
        simulate_bank(bank, params, keys, window=0)
    monkeypatch.setenv("REPRO_TICK_WINDOW", "3")
    res_env = simulate_bank(bank, params, keys)  # window=None -> env
    monkeypatch.delenv("REPRO_TICK_WINDOW")
    res_3 = simulate_bank(bank, params, keys, window=3)
    _assert_bitwise(res_env, res_3, msg="env window ")


def test_window_sizes_share_no_trace_but_repeat_free():
    """Each window size is its own static shape (one trace), and repeated
    runs at one size stay retrace-free."""
    bank = build_bank(n=2, seed=1, max_ticks=200)
    params = make_bank_params(bank)
    keys = _keys(2, 1, seed=1)
    reset_bank_trace_count()
    with count_bank_traces() as tr:
        simulate_bank(bank, params, keys, lowering="banked", window=4)
        simulate_bank(bank, params, keys, lowering="banked", window=4)
    assert tr.count == 1
    with count_bank_traces() as tr2:
        simulate_bank(bank, params, keys, lowering="banked", window=8)
    assert tr2.count == 1


# ---------------------------------------------------------------------------
# checkpoint/resume of the stepped loop
# ---------------------------------------------------------------------------

def test_stepped_checkpoint_resume_bitwise(tmp_path):
    """Snapshots taken mid-run by the stepped loop resume to the exact same
    result: each window is a pure function of the carry, so cutting the run
    at any window boundary and restarting from the snapshot is a no-op."""
    from repro.core.engine import BankCheckpoint

    bank = build_bank(n=4, seed=11, max_ticks=2_000)
    params = make_bank_params(bank, bg_mu=4.0, bg_sigma=1.5)
    keys = _keys(4, 2, seed=11)
    ref = simulate_bank(bank, params, keys, lowering="banked", window=8,
                        bucketed=False)

    snaps = []
    full = simulate_bank_stepped(
        bank, params, keys, window=8,
        checkpoint_every=3, on_checkpoint=snaps.append,
    )
    _assert_bitwise(ref, full, msg="checkpointing run ")
    assert snaps, "expected at least one snapshot"
    assert all(isinstance(s, BankCheckpoint) for s in snaps)
    # snapshots live on host memory: they must survive the donated carry
    for s in snaps:
        resumed = simulate_bank_stepped(bank, params, keys, window=8,
                                        resume=s)
        _assert_bitwise(ref, resumed, msg=f"resume@{s.windows_done} ")

    # a snapshot taken at one window size cannot seed another
    with pytest.raises(ValueError, match="window"):
        simulate_bank_stepped(bank, params, keys, window=4, resume=snaps[0])

    # Fleet.save_checkpoint/load_checkpoint round-trip the snapshot
    fleet = Fleet(bank)
    fleet.save_checkpoint(tmp_path, snaps[-1], include_fleet=False)
    loaded = Fleet.load_checkpoint(tmp_path)
    assert loaded.windows_done == snaps[-1].windows_done
    assert loaded.window == snaps[-1].window
    resumed = simulate_bank_stepped(bank, params, keys, window=8,
                                    resume=loaded)
    _assert_bitwise(ref, resumed, msg="resume from disk ")


# ---------------------------------------------------------------------------
# persisted window autotuner table
# ---------------------------------------------------------------------------

def test_window_table_roundtrip(tmp_path, monkeypatch):
    """default_tick_window reads the persisted per-backend sweep table;
    record_window_sweep is its writer (read-modify-write)."""
    from repro.core import engine as engine_lib

    table = tmp_path / "window_table.json"
    monkeypatch.setenv("REPRO_WINDOW_TABLE", str(table))
    engine_lib._load_window_table.cache_clear()
    try:
        # missing table -> hardcoded fallback
        assert default_tick_window() >= 1
        engine_lib.record_window_sweep("cpu", tick=4)
        engine_lib.record_window_sweep("cpu", leap=2)  # must keep tick=4
        assert default_tick_window() == 4
        assert default_tick_window(leap=True) == 2
        # corrupt table -> tolerated, falls back
        table.write_text("{not json")
        engine_lib._load_window_table.cache_clear()
        assert default_tick_window() >= 1
    finally:
        engine_lib._load_window_table.cache_clear()
