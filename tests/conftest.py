import os
import sys

sys.path.insert(0, os.path.dirname(__file__))  # for `helpers` imports

# Tests and benches see the single real CPU device; ONLY launch/dryrun.py
# forces 512 virtual devices. Keep determinism + x64-off defaults explicit.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("REPRO_KERNEL_BACKEND", "auto")

import jax  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)
