"""ScenarioBank semantics: the banked engine must match per-scenario
``simulate()`` and the plain-Python oracle leg for leg, run every scenario in
one jit trace, and keep the padding contract inert."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.calibration import (
    PriorBox,
    make_bank_theta_mapper,
    presimulate_bank,
    validate_bank,
)
from repro.core.engine import (
    SimSpec,
    count_bank_traces,
    make_bank_params,
    make_params,
    reset_bank_trace_count,
    simulate,
    simulate_bank,
)
from repro.core.refsim import reference_simulate
from repro.core.scenarios import build_bank, family_names, sample_scenarios
from repro.core.workload import compile_bank

N_FAMILIES = len(family_names())


def _bank(n=8, seed=0, max_ticks=20_000, **kw):
    return build_bank(n=n, seed=seed, max_ticks=max_ticks, **kw)


def _assert_bank_matches_scenario(bank, res, i, ref, r=0, atol=1e-5):
    nt = int(bank.n_legs[i])
    pick = lambda a: np.asarray(a)[i, r, :nt]
    for field in ("transfer_time", "conth_mb", "conpr_mb", "start_tick"):
        np.testing.assert_allclose(
            pick(getattr(res, field)),
            np.asarray(ref[field] if isinstance(ref, dict) else getattr(ref, field)),
            rtol=1e-5, atol=atol, err_msg=f"scenario {i} field {field}",
        )
    ref_done = ref["done"] if isinstance(ref, dict) else np.asarray(ref.done)
    np.testing.assert_array_equal(pick(res.done), ref_done,
                                  err_msg=f"scenario {i} done")


def test_bank_has_heterogeneous_shapes():
    bank = _bank(n=N_FAMILIES)
    assert bank.n_scenarios == N_FAMILIES
    # the fleet is genuinely heterogeneous: shapes differ across scenarios
    assert len({int(n) for n in bank.n_legs}) > 1
    assert len({int(n) for n in bank.n_links}) > 1
    # padding contract: padded legs carry no size, no incidence
    for i in range(bank.n_scenarios):
        nt = int(bank.n_legs[i])
        assert (bank.size_mb[i, nt:] == 0).all()
        assert (bank.leg_proc[i, nt:] == 0).all()
        assert (bank.leg_link[i, nt:] == 0).all()
        assert not bank.leg_valid[i, nt:].any()
        nl = int(bank.n_links[i])
        assert (bank.bandwidth[i, nl:] == 0).all()


@pytest.mark.parametrize("leap", [False, True])
def test_bank_matches_per_scenario_and_oracle(leap):
    """>= 8 heterogeneous scenarios x 2 replicas: the banked run must agree
    leg-for-leg with the per-scenario engine AND the loop-based oracle under
    deterministic background load (the families use sigma=0)."""
    n = max(8, N_FAMILIES)
    bank = _bank(n=n)
    params = make_bank_params(bank)
    keys = jax.random.split(jax.random.PRNGKey(0), n * 2).reshape(n, 2, 2)
    res = simulate_bank(bank, params, keys, leap=leap)
    assert res.transfer_time.shape == (n, 2, bank.pad_legs)

    for i in range(n):
        table = bank.scenario_table(i)
        spec = SimSpec.from_table(table, max_ticks=int(bank.max_ticks[i]))
        p = make_params(table)
        for r in range(2):
            ref = simulate(spec, p, keys[i, r], leap=leap)
            _assert_bank_matches_scenario(bank, res, i, ref, r=r)
            if leap:
                continue
            assert int(res.ticks[i, r]) == int(ref.ticks)
        # plain-Python oracle (tick semantics; deterministic bg)
        if not leap:
            oracle = reference_simulate(
                table,
                np.asarray(p.keep_frac),
                np.asarray(p.bg_mu),
                np.asarray(p.bg_sigma),
                int(bank.max_ticks[i]),
            )
            _assert_bank_matches_scenario(bank, res, i, oracle, r=0, atol=1e-3)


def test_bank_padding_is_inert():
    """Growing the pads must not change any real leg's observations."""
    pairs = sample_scenarios(n=4, seed=3)
    small = compile_bank(pairs, max_ticks=20_000)
    big = compile_bank(
        pairs, max_ticks=20_000,
        pad_legs=small.pad_legs + 13,
        pad_procs=small.pad_procs + 7,
        pad_links=small.pad_links + 5,
    )
    keys = jax.random.split(jax.random.PRNGKey(1), 4).reshape(4, 1, 2)
    r_small = simulate_bank(small, make_bank_params(small), keys)
    r_big = simulate_bank(big, make_bank_params(big), keys)
    for i in range(4):
        nt = int(small.n_legs[i])
        for f in ("transfer_time", "conth_mb", "conpr_mb", "done"):
            np.testing.assert_allclose(
                np.asarray(getattr(r_small, f))[i, 0, :nt],
                np.asarray(getattr(r_big, f))[i, 0, :nt],
                rtol=1e-6, atol=1e-6, err_msg=f,
            )
    # padded legs are born done and transfer nothing
    pad = ~np.broadcast_to(big.leg_valid[:, None, :], r_big.done.shape)
    assert np.asarray(r_big.done)[pad].all()
    assert (np.asarray(r_big.transfer_time)[pad] == 0).all()


@pytest.mark.slow
def test_bank_64_scenarios_single_trace():
    """64 heterogeneous scenarios x 2 replicas in ONE jit trace, and a second
    fleet of the same padded shape reuses it (zero retraces)."""
    pads = dict(pad_legs=64, pad_procs=64, pad_links=8)
    bank = _bank(n=64, seed=0, **pads)
    params = make_bank_params(bank)
    keys = jax.random.split(jax.random.PRNGKey(0), 64 * 2).reshape(64, 2, 2)
    # order-independent trace accounting: drop whatever earlier tests cached
    reset_bank_trace_count()
    with count_bank_traces() as traces:
        res = simulate_bank(bank, params, keys, leap=True)
        res.done.block_until_ready()
    assert traces.count == 1
    # stratified parity against the per-scenario engine (full sweep is the
    # oracle test above; here we guard the at-scale path)
    for i in range(0, 64, 8):
        table = bank.scenario_table(i)
        spec = SimSpec.from_table(table, max_ticks=int(bank.max_ticks[i]))
        ref = simulate(spec, make_params(table), keys[i, 0], leap=True)
        _assert_bank_matches_scenario(bank, res, i, ref, r=0)
    # a *different* fleet, same pads -> same trace
    bank2 = _bank(n=64, seed=1000, **pads)
    with count_bank_traces() as retraces:
        res2 = simulate_bank(bank2, make_bank_params(bank2), keys, leap=True)
        res2.done.block_until_ready()
    assert retraces.count == 0
    valid2 = np.broadcast_to(bank2.leg_valid[:, None, :], res2.done.shape)
    assert np.asarray(res2.done)[valid2].all()


def test_make_bank_params_protocol_override():
    bank = _bank(n=N_FAMILIES)
    params = make_bank_params(bank, overhead=0.25, protocol="webdav")
    pid = bank.protocol_names.index("webdav")
    keep = np.asarray(params.keep_frac)
    webdav = bank.protocol_id == pid
    assert np.allclose(keep[webdav], 0.75)
    other = bank.leg_valid & ~webdav
    assert np.allclose(keep[other], bank.keep_frac[other])
    assert np.allclose(keep[~bank.leg_valid], 1.0)  # padding untouched


def test_bank_theta_mapper_matches_scalar_mapper():
    """The bank mapper must agree with the per-table mapper on every valid
    slot (unified protocol namespace notwithstanding)."""
    from repro.core.calibration import make_theta_mapper

    bank = _bank(n=4, seed=5)
    theta = jnp.array([0.07, 12.0, 3.0])
    bank_params = make_bank_theta_mapper(bank, "webdav")(theta)
    for i in range(4):
        table = bank.scenario_table(i)
        if "webdav" not in table.protocol_names:
            continue
        ref = make_theta_mapper(table, "webdav")(theta)
        nt, nl = table.n_legs, table.n_links
        np.testing.assert_allclose(
            np.asarray(bank_params.keep_frac)[i, :nt], np.asarray(ref.keep_frac),
            rtol=1e-6,
        )
        np.testing.assert_allclose(
            np.asarray(bank_params.bg_mu)[i, :nl], np.asarray(ref.bg_mu),
            rtol=1e-6,
        )


def test_presimulate_bank_shapes_and_finiteness():
    # Eq.-1 coefficients need remote-access observations: draw the fleet
    # from remote-bearing families
    bank = build_bank(
        ["wlcg-remote", "bursty"], n=3, seed=7, max_ticks=20_000
    )
    theta, x, sid = presimulate_bank(
        bank, PriorBox.paper(), jax.random.PRNGKey(0), 6, batch=3, leap=True,
    )
    assert theta.shape == (18, 3) and x.shape == (18, 3) and sid.shape == (18,)
    assert np.isfinite(np.asarray(x)).all()
    assert (np.bincount(np.asarray(sid), minlength=3) == 6).all()
    lo, hi = PriorBox.paper().low, PriorBox.paper().high
    assert (np.asarray(theta) >= np.asarray(lo) - 1e-6).all()
    assert (np.asarray(theta) <= np.asarray(hi) + 1e-6).all()


def test_validate_bank_per_scenario_errors():
    bank = build_bank(
        ["wlcg-remote", "bursty"], n=3, seed=9, max_ticks=20_000
    )
    val = validate_bank(
        bank,
        jnp.array([0.02, 1.0, 0.0]),
        jnp.array([0.02, 0.03, 0.001]),
        jax.random.PRNGKey(2),
        n_sims=4,
    )
    assert val["median_coef"].shape == (3, 3)
    assert val["mean_abs_error"].shape == (3, 3)
    assert val["sum_error"].shape == (3, 4)
    assert len(val["scenario_names"]) == 3
    assert np.isfinite(val["coefficients"]).all()
