"""End-to-end behaviour tests for the paper's system: grid description ->
simulation -> analysis -> calibration handoff -> profile optimization, plus
the dry-run machinery on a small mesh (everything a user touches, wired
together)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dataset import fit_profile, observations
from repro.core.engine import SimSpec, make_params, simulate
from repro.core.topology import Grid
from repro.core.workload import (
    AccessProfileKind,
    Campaign,
    FileAccess,
    Job,
    ProfileTag,
    Replica,
    compile_campaign,
    wlcg_production_workload,
)


def _demo_grid():
    g = Grid()
    g.add_data_center("CERN")
    g.add_data_center("GRIF")
    g.add_storage_element("grif_se", "GRIF")
    g.add_storage_element("cern_se", "CERN")
    g.add_worker_node("wn", "CERN")
    g.add_link("grif_se", "cern_se", 1250.0, bg_mu=5.0, bg_sigma=1.0)
    g.add_link("grif_se", "wn", 1250.0, bg_mu=36.9, bg_sigma=14.4)
    g.add_link("cern_se", "wn", 2500.0)
    return g


def test_three_profiles_end_to_end():
    """One job exercising all three access profiles produces analyzable
    observations for each, and profile-appropriate regressions fit."""
    g = _demo_grid()
    rng = np.random.RandomState(0)
    accs = []
    for i in range(9):
        size = float(rng.uniform(300, 1500))
        kind = [AccessProfileKind.REMOTE, AccessProfileKind.STAGE_IN,
                AccessProfileKind.DATA_PLACEMENT][i % 3]
        src = "cern_se" if kind is AccessProfileKind.STAGE_IN else "grif_se"
        accs.append(FileAccess(
            Replica(size, src), kind,
            {0: "webdav", 1: "xrdcp", 2: "gsiftp"}[i % 3],
            local_storage_element="cern_se",
        ))
    table = compile_campaign(g, Campaign((Job("wn", tuple(accs)),)))
    # placement contributes 2 legs
    assert table.n_legs == 3 + 3 + 3 * 2
    spec = SimSpec.from_table(table, max_ticks=60_000)
    res = simulate(spec, make_params(table), jax.random.PRNGKey(0), leap=True)
    assert bool(np.asarray(res.done).all())
    for tag in (ProfileTag.REMOTE, ProfileTag.STAGE_IN, ProfileTag.PLACEMENT):
        ds = observations(res, tag)
        assert int(ds.valid.sum()) >= 3
        fit = fit_profile(ds, tag)
        assert np.asarray(fit.coef)[0] > 0  # time grows with size


def test_uni_directional_link_enforcement():
    g = _demo_grid()
    # reverse direction requires its own link
    with pytest.raises(KeyError):
        g.link("cern_se", "grif_se")
    # WN -> SE links are rejected (data input only)
    with pytest.raises(ValueError):
        g.add_link("wn", "grif_se", 100.0)


def test_production_workload_structure():
    """The Section-5 workload reconstruction: 106 observations, <=12 jobs,
    <=4 threads per wave, 300MB-3GB files, single WAN link."""
    grid, camp = wlcg_production_workload(seed=0)
    table = compile_campaign(grid, camp)
    assert table.n_legs == 106
    assert table.n_links == 1
    assert len(camp.jobs) <= 12
    assert (table.size_mb >= 300).all() and (table.size_mb <= 3000).all()
    assert (table.profile == ProfileTag.REMOTE).all()
    # threads share per-(job, link) processes
    assert table.n_procs <= len(camp.jobs)


def test_calibration_artifacts_shape():
    """The calibration produces all artifacts the paper reports (posterior
    samples, theta*, classifier) at a token scale."""
    from repro.core.calibration import CalibrationConfig, calibrate

    grid, camp = wlcg_production_workload(n_observations=24, seed=0)
    table = compile_campaign(grid, camp)
    spec = SimSpec.from_table(table, max_ticks=20_000)
    cfg = CalibrationConfig(n_presim=256, epochs=3, batch_size=128,
                            n_chains=2, n_mcmc=500, burn_in=100)
    res = calibrate(spec, table, jnp.array([0.03, 0.03, 0.001]),
                    jax.random.PRNGKey(0), cfg)
    assert res.theta_star.shape == (3,)
    assert res.theta_map.shape == (3,)
    assert res.posterior_samples.shape[1] == 3
    lo = jnp.array([0.0, 0.0, 0.0])
    hi = jnp.array([0.1, 100.0, 100.0])
    assert bool(((res.posterior_samples >= lo) & (res.posterior_samples <= hi)).all())
    assert 0.0 < float(res.accept_rate) <= 1.0


def test_sharding_rules_cover_every_param():
    """Every parameter leaf of every architecture gets a PartitionSpec whose
    rank does not exceed the leaf's (no rule falls through to a mis-ranked
    spec)."""
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_smoke_config, list_archs
    from repro.models import model as M
    from repro.parallel import sharding as SH

    for arch in list_archs():
        cfg = get_smoke_config(arch)
        params = jax.eval_shape(lambda k, c=cfg: M.init_params(k, c),
                                jax.random.PRNGKey(0))
        specs = SH.tree_specs(params, ("pod", "data", "model"))
        leaves_p, _ = jax.tree_util.tree_flatten(params)
        leaves_s, _ = jax.tree_util.tree_flatten(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(leaves_p) == len(leaves_s)
        for p, s in zip(leaves_p, leaves_s):
            assert len(s) <= len(p.shape), (arch, p.shape, s)
